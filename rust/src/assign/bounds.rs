//! Search-space bounds for Φ_c (paper §III-A2, eqs. 5–7).
//!
//! `Φ⁻` (eq. 6) takes each group in isolation: `x_k` is the minimal number
//! of slots to drain group k if it were the job's only group — a per-group
//! water level. `Φ⁺` (eq. 5) imagines every available server receiving all
//! the tasks of every group it can serve. OBTA searches only `[Φ⁻, Φ⁺]`;
//! the `water_level` routine here is also the inner step of WF (eq. 9) and
//! of the OCWF-ACC early-exit test (§IV).

use crate::job::{ServerId, Slots, TaskCount};
use crate::util::ceil_div;

use super::Instance;

/// Minimal integer level `x` such that
/// `Σ_{m ∈ servers} max(x − busy[m], 0) · mu[m] ≥ size`  (eqs. 7/9).
///
/// Returns 0 for `size == 0`. Found by binary search; the bracket
/// `hi = max(busy) + ceil(size / Σμ)` is always sufficient.
pub fn water_level(servers: &[ServerId], size: TaskCount, busy: &[Slots], mu: &[u64]) -> Slots {
    if size == 0 {
        return 0;
    }
    assert!(!servers.is_empty());
    let max_busy = servers.iter().map(|&m| busy[m]).max().unwrap();
    let sum_mu: u64 = servers.iter().map(|&m| mu[m]).sum();
    assert!(sum_mu > 0, "water_level: zero total capacity");
    let mut lo = 1;
    let mut hi = max_busy + ceil_div(size, sum_mu);
    debug_assert!(level_capacity(servers, hi, busy, mu) >= size as u128);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if level_capacity(servers, mid, busy, mu) >= size as u128 {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    hi
}

/// Task capacity available below level `x`: `Σ max(x − busy, 0)·μ`.
#[inline]
fn level_capacity(servers: &[ServerId], x: Slots, busy: &[Slots], mu: &[u64]) -> u128 {
    servers
        .iter()
        .map(|&m| x.saturating_sub(busy[m]) as u128 * mu[m] as u128)
        .sum()
}

/// Lower bound Φ⁻ (eq. 6): the max over groups of the isolated water
/// level `x_k`.
pub fn phi_lower(inst: &Instance) -> Slots {
    inst.groups
        .iter()
        .filter(|g| g.size > 0)
        .map(|g| water_level(&g.servers, g.size, inst.busy, inst.mu))
        .max()
        .unwrap_or(0)
}

/// Upper bound Φ⁺ (eq. 5): for each available server m, pretend every
/// task of every group that can use m is assigned to m.
pub fn phi_upper(inst: &Instance) -> Slots {
    let union = inst.union_servers();
    union
        .iter()
        .map(|&m| {
            let tasks: TaskCount = inst
                .groups
                .iter()
                .filter(|g| g.size > 0 && g.servers.contains(&m))
                .map(|g| g.size)
                .sum();
            inst.busy[m] + ceil_div(tasks, inst.mu[m])
        })
        .max()
        .unwrap_or(0)
}

/// A trivial upper bound that uses no narrowing at all — the widest window
/// a solver without §III-A2's analysis would face. Used by NLIP. It is
/// always achievable: assign each group entirely to one of its servers;
/// even if all groups pile onto one server the finish time is at most
/// `max busy + Σ_k ceil(|T_k}| / min μ)`.
pub fn phi_upper_trivial(inst: &Instance) -> Slots {
    let union = inst.union_servers();
    if union.is_empty() {
        return 0;
    }
    let max_busy = union.iter().map(|&m| inst.busy[m]).max().unwrap();
    let min_mu = union.iter().map(|&m| inst.mu[m]).min().unwrap().max(1);
    let total_slots: Slots = inst
        .groups
        .iter()
        .filter(|g| g.size > 0)
        .map(|g| ceil_div(g.size, min_mu))
        .sum();
    max_busy + total_slots
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::TaskGroup;

    #[test]
    fn water_level_basic() {
        // Two idle servers, μ = [2, 3]: level 1 holds 5 tasks, level 2
        // holds 10.
        let busy = vec![0, 0];
        let mu = vec![2, 3];
        assert_eq!(water_level(&[0, 1], 5, &busy, &mu), 1);
        assert_eq!(water_level(&[0, 1], 6, &busy, &mu), 2);
        assert_eq!(water_level(&[0, 1], 10, &busy, &mu), 2);
        assert_eq!(water_level(&[0, 1], 11, &busy, &mu), 3);
    }

    #[test]
    fn water_level_with_busy_servers() {
        // Server 0 busy until 4, server 1 idle, μ = 1 each.
        let busy = vec![4, 0];
        let mu = vec![1, 1];
        // 4 tasks fit on server 1 alone by level 4.
        assert_eq!(water_level(&[0, 1], 4, &busy, &mu), 4);
        // 5 tasks: level 5 gives 5 (server1) + 1 (server0) >= 5 → but
        // level 4 gives only 4, so 5... check: level 5: (5-4)*1 + 5 = 6 ≥ 5;
        // level 4: 0 + 4 = 4 < 5. So 5.
        assert_eq!(water_level(&[0, 1], 5, &busy, &mu), 5);
    }

    #[test]
    fn water_level_zero_size() {
        assert_eq!(water_level(&[0], 0, &[3], &[1]), 0);
    }

    #[test]
    fn water_level_single_server_is_ceil() {
        let busy = vec![7];
        let mu = vec![3];
        assert_eq!(water_level(&[0], 10, &busy, &mu), 7 + 4);
    }

    #[test]
    fn water_level_minimality_property() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(77);
        for _ in 0..200 {
            let m = 1 + rng.gen_range(6) as usize;
            let busy: Vec<u64> = (0..m).map(|_| rng.gen_range(20)).collect();
            let mu: Vec<u64> = (0..m).map(|_| rng.gen_range_incl(1, 5)).collect();
            let servers: Vec<usize> = (0..m).collect();
            let size = rng.gen_range_incl(1, 200);
            let x = water_level(&servers, size, &busy, &mu);
            assert!(level_capacity(&servers, x, &busy, &mu) >= size as u128);
            if x > 0 {
                assert!(
                    level_capacity(&servers, x - 1, &busy, &mu) < size as u128,
                    "level {x} not minimal for size {size}"
                );
            }
        }
    }

    #[test]
    fn bounds_bracket_sanity() {
        let groups = vec![
            TaskGroup::new(10, vec![0, 1]),
            TaskGroup::new(6, vec![1, 2]),
        ];
        let mu = vec![2, 2, 2];
        let busy = vec![0, 3, 1];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let lo = phi_lower(&inst);
        let hi = phi_upper(&inst);
        let triv = phi_upper_trivial(&inst);
        assert!(lo <= hi, "lo {lo} hi {hi}");
        assert!(hi <= triv, "narrowed {hi} vs trivial {triv}");
        assert!(lo >= 1);
    }

    #[test]
    fn phi_upper_matches_formula() {
        // Single group of 9 tasks on servers {0,1}; μ=3, busy=[2,0].
        let groups = vec![TaskGroup::new(9, vec![0, 1])];
        let mu = vec![3, 3];
        let busy = vec![2, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        // Server 0: 2 + ceil(9/3) = 5; server 1: 0 + 3 = 3. Max = 5.
        assert_eq!(phi_upper(&inst), 5);
        // Φ⁻: water level: level 3 → (1)*3 + 3*3 = 12 ≥ 9; level 2 →
        // 0+... (2-2)*3 + 2*3 = 6 < 9. So 3.
        assert_eq!(phi_lower(&inst), 3);
    }

    #[test]
    fn empty_job_all_bounds_zero() {
        let groups: Vec<TaskGroup> = vec![];
        let mu = vec![1];
        let busy = vec![0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        assert_eq!(phi_lower(&inst), 0);
        assert_eq!(phi_upper(&inst), 0);
        assert_eq!(phi_upper_trivial(&inst), 0);
    }
}
