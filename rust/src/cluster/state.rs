//! The unified cluster-state core shared by the assignment layer
//! ([`crate::assign`]), the reordering scheduler ([`crate::sched::ocwf`])
//! and both simulation engines ([`crate::sim`]).
//!
//! Before this module existed every layer carried its own ad-hoc
//! `Vec<Slots>` busy vector and the reordered engine inlined its queue
//! drain logic as a closure. The three pieces here factor that state out:
//!
//! - [`ClusterState`] — the per-server estimated busy times `b_m` (eq. 2),
//!   with allocation-free reset/reload so hot loops can reuse one
//!   instance across arrivals and reorder rounds.
//! - [`ServerQueues`] — per-server FIFO queues of job task batches with
//!   the *analytic* drain (entry-by-entry, no slot stepping) the
//!   reordered engine uses between arrivals.
//! - [`JobProgress`] — per-job remaining-task and completion bookkeeping
//!   that draining updates.
//!
//! All three are plain data + methods: no interior mutability, no
//! threading assumptions. Parallel candidate evaluation in the OCWF
//! driver shares a `ClusterState` immutably during a round and mutates it
//! only between rounds.

use crate::assign::Instance;
use crate::job::{Job, ServerId, Slots, TaskCount, TaskGroup};
use crate::util::ceil_div;

/// Per-server estimated busy times `b_m^c` (eq. 2): the number of whole
/// slots each server needs to drain its current queue. This is the state
/// every assigner scores candidate allocations against.
#[derive(Clone, Debug, Default)]
pub struct ClusterState {
    busy: Vec<Slots>,
}

impl ClusterState {
    pub fn new(num_servers: usize) -> Self {
        ClusterState {
            busy: vec![0; num_servers],
        }
    }

    pub fn num_servers(&self) -> usize {
        self.busy.len()
    }

    /// The busy-time vector, for building [`Instance`]s.
    #[inline]
    pub fn busy(&self) -> &[Slots] {
        &self.busy
    }

    /// Mutable access for engines that compute busy times directly from
    /// their own queue representation (e.g. the slot-stepping validator).
    #[inline]
    pub fn busy_mut(&mut self) -> &mut [Slots] {
        &mut self.busy
    }

    /// Resize to `num_servers` and zero every entry, reusing the existing
    /// allocation (the OCWF driver resets to "all servers empty" at the
    /// start of every reorder round sequence — Alg. 3 line 4).
    pub fn reset(&mut self, num_servers: usize) {
        self.busy.clear();
        self.busy.resize(num_servers, 0);
    }

    /// Load busy times from absolute queue-empty slots: `b_m = max(free_m
    /// − now, 0)` (the FIFO engine's arrival-time view).
    pub fn observe_free(&mut self, free: &[Slots], now: Slots) {
        self.busy.clear();
        self.busy
            .extend(free.iter().map(|&f| f.saturating_sub(now)));
    }

    /// Overwrite from a computed busy vector (e.g. WF's post-assignment
    /// `b_m(K_c)`), reusing the allocation.
    pub fn copy_from(&mut self, src: &[Slots]) {
        self.busy.clear();
        self.busy.extend_from_slice(src);
    }

    /// View this state as an assignment-problem instance for one job.
    pub fn instance<'a>(&'a self, groups: &'a [TaskGroup], mu: &'a [u64]) -> Instance<'a> {
        Instance {
            groups,
            mu,
            busy: &self.busy,
        }
    }

    /// Reserved capacity of the internal buffer (allocation-stability
    /// tests).
    pub fn footprint(&self) -> usize {
        self.busy.capacity()
    }
}

/// One queue entry: the tasks of one job assigned to one server, split by
/// task group (`(group index, tasks)` with tasks > 0).
#[derive(Clone, Debug)]
pub struct QueueEntry {
    pub job: usize,
    pub parts: Vec<(usize, TaskCount)>,
}

impl QueueEntry {
    pub fn total(&self) -> TaskCount {
        self.parts.iter().map(|&(_, n)| n).sum()
    }
}

/// Per-job progress bookkeeping updated by [`ServerQueues::drain`].
#[derive(Clone, Debug)]
pub struct JobProgress {
    /// Remaining tasks per group (aligned with `jobs[i].groups`).
    pub remaining: Vec<Vec<TaskCount>>,
    /// Total remaining tasks per job.
    pub total_remaining: Vec<TaskCount>,
    /// Absolute completion slot, once all of a job's tasks finished.
    pub completion: Vec<Option<Slots>>,
    /// Latest finish observed so far per job (starts at the arrival).
    pub last_finish: Vec<Slots>,
    /// Earliest slot at which any of a job's tasks made progress — the
    /// service side of the latency decomposition (`wait = first_start −
    /// arrival`, `service = jct − wait`). `None` until the job first
    /// runs (or forever, for zero-task jobs, which wait 0 by
    /// definition).
    pub first_start: Vec<Option<Slots>>,
}

impl JobProgress {
    pub fn new(jobs: &[Job]) -> Self {
        JobProgress {
            remaining: jobs
                .iter()
                .map(|j| j.groups.iter().map(|g| g.size).collect())
                .collect(),
            total_remaining: jobs.iter().map(|j| j.total_tasks()).collect(),
            completion: vec![None; jobs.len()],
            last_finish: jobs.iter().map(|j| j.arrival).collect(),
            first_start: vec![None; jobs.len()],
        }
    }

    /// An empty progress table for streaming runs: jobs are appended by
    /// [`JobProgress::push_job`] as the source yields them.
    pub fn empty() -> Self {
        JobProgress {
            remaining: Vec::new(),
            total_remaining: Vec::new(),
            completion: Vec::new(),
            last_finish: Vec::new(),
            first_start: Vec::new(),
        }
    }

    /// Append one job's progress rows (streaming ingestion). The job's id
    /// must equal the current length — the same id-equals-position
    /// contract as [`JobProgress::new`].
    pub fn push_job(&mut self, job: &Job, spare: &mut Vec<Vec<TaskCount>>) {
        debug_assert_eq!(job.id, self.remaining.len());
        let mut row = spare.pop().unwrap_or_default();
        row.clear();
        row.extend(job.groups.iter().map(|g| g.size));
        self.remaining.push(row);
        self.total_remaining.push(job.total_tasks());
        self.completion.push(None);
        self.last_finish.push(job.arrival);
        self.first_start.push(None);
    }

    /// Record that `job` made progress at slot `t`, keeping the minimum
    /// (a job's work can start on several servers; the wait ends at the
    /// earliest).
    #[inline]
    pub fn note_start(&mut self, job: usize, t: Slots) {
        match self.first_start[job] {
            Some(s) if s <= t => {}
            _ => self.first_start[job] = Some(t),
        }
    }

    /// Reclaim a retired job's per-group row into the spare pool (its
    /// scalar slots stay — they are O(1) per job). Streaming eviction.
    pub fn reclaim(&mut self, job: usize, spare: &mut Vec<Vec<TaskCount>>) {
        let row = std::mem::take(&mut self.remaining[job]);
        if row.capacity() > 0 {
            spare.push(row);
        }
    }

    pub fn all_complete(&self) -> bool {
        self.completion.iter().all(|c| c.is_some())
    }

    /// Number of jobs without a recorded completion (horizon-error
    /// reporting).
    pub fn unfinished(&self) -> usize {
        self.completion.iter().filter(|c| c.is_none()).count()
    }

    /// Assemble the per-job JCT vector (`completion − arrival`, in job
    /// order) and the makespan. One definition shared by the analytic
    /// reordered engine and the DES engine, so the outcome derivation —
    /// part of their bit-equivalence contract — cannot silently diverge.
    /// Panics unless [`JobProgress::all_complete`].
    pub fn jcts_and_makespan(&self, jobs: &[Job]) -> (Vec<Slots>, Slots) {
        let jcts: Vec<Slots> = jobs
            .iter()
            .zip(&self.completion)
            .map(|(j, c)| c.expect("job must be complete") - j.arrival)
            .collect();
        let makespan = self
            .completion
            .iter()
            .map(|c| c.unwrap())
            .max()
            .unwrap_or(0);
        (jcts, makespan)
    }

    /// Assemble the per-job queueing-wait vector (`first_start −
    /// arrival`, in job order; 0 for jobs that never recorded a start).
    /// Companion of [`JobProgress::jcts_and_makespan`] — together they
    /// give the `jct = wait + service` decomposition.
    pub fn waits(&self, jobs: &[Job]) -> Vec<Slots> {
        jobs.iter()
            .zip(&self.first_start)
            .map(|(j, s)| s.map_or(0, |t| t.saturating_sub(j.arrival)))
            .collect()
    }

    /// [`JobProgress::waits`] for streaming runs, where only the arrival
    /// slots remain resident.
    pub fn waits_from(&self, arrivals: &[Slots]) -> Vec<Slots> {
        debug_assert_eq!(arrivals.len(), self.first_start.len());
        arrivals
            .iter()
            .zip(&self.first_start)
            .map(|(a, s)| s.map_or(0, |t| t.saturating_sub(*a)))
            .collect()
    }

    /// [`JobProgress::jcts_and_makespan`] for streaming runs, where job
    /// payloads were evicted and only the arrival slots (O(1) per job)
    /// remain resident.
    pub fn jcts_and_makespan_from(&self, arrivals: &[Slots]) -> (Vec<Slots>, Slots) {
        debug_assert_eq!(arrivals.len(), self.completion.len());
        let jcts: Vec<Slots> = arrivals
            .iter()
            .zip(&self.completion)
            .map(|(a, c)| c.expect("job must be complete") - a)
            .collect();
        let makespan = self
            .completion
            .iter()
            .map(|c| c.unwrap())
            .max()
            .unwrap_or(0);
        (jcts, makespan)
    }
}

/// A destination for grouped queue entries: anything that can recycle a
/// parts buffer and accept one `(server, job, parts)` entry. Implemented
/// by [`ServerQueues`] (the analytic reordered engine) and by the DES
/// engine's run queues ([`crate::des`]), so both engines share the pooled
/// [`QueueRebuild`] grouping path instead of duplicating it.
pub trait EntrySink {
    /// Take a cleared parts buffer from the sink's recycle pool (fresh
    /// when the pool is empty).
    fn take_parts(&mut self) -> Vec<(usize, TaskCount)>;
    /// Append one grouped entry to `server`'s queue.
    fn push_entry(&mut self, server: ServerId, job: usize, parts: Vec<(usize, TaskCount)>);
}

/// Per-server FIFO queues of [`QueueEntry`]s with analytic draining —
/// the reordered engine's execution substrate. Queues are rebuilt from
/// scratch on every arrival (OCWF reassigns every remaining task), so
/// retiring an entry — whether by [`ServerQueues::clear`] before a
/// rebuild or by [`ServerQueues::drain`] between arrivals — recycles its
/// `parts` buffer into a spare pool that [`ServerQueues::take_parts`]
/// hands back out. After one warmup cycle the pool covers the workload's
/// high-water mark and the rebuild path stops allocating (asserted by
/// `rust/tests/alloc_stability.rs`).
#[derive(Clone, Debug, Default)]
pub struct ServerQueues {
    queues: Vec<Vec<QueueEntry>>,
    /// Recycled `QueueEntry::parts` buffers (cleared, capacity kept).
    spare: Vec<Vec<(usize, TaskCount)>>,
}

impl ServerQueues {
    pub fn new(num_servers: usize) -> Self {
        ServerQueues {
            queues: vec![Vec::new(); num_servers],
            spare: Vec::new(),
        }
    }

    /// Drop every entry, keeping the per-server queue allocations and
    /// recycling each entry's parts buffer into the spare pool.
    pub fn clear(&mut self) {
        let ServerQueues { queues, spare } = self;
        for q in queues.iter_mut() {
            for mut e in q.drain(..) {
                e.parts.clear();
                spare.push(e.parts);
            }
        }
    }

    pub fn push(&mut self, server: ServerId, entry: QueueEntry) {
        self.queues[server].push(entry);
    }

    /// Take a cleared parts buffer from the spare pool (empty-but-warm
    /// capacity when available, a fresh vector otherwise).
    pub fn take_parts(&mut self) -> Vec<(usize, TaskCount)> {
        self.spare.pop().unwrap_or_default()
    }

    /// Reserved capacity across queues, live entries and the spare pool
    /// (allocation-stability tests).
    pub fn footprint(&self) -> usize {
        self.queues.capacity()
            + self
                .queues
                .iter()
                .map(|q| q.capacity() + q.iter().map(|e| e.parts.capacity()).sum::<usize>())
                .sum::<usize>()
            + self.spare.capacity()
            + self.spare.iter().map(|v| v.capacity()).sum::<usize>()
    }

    /// Advance every server's queue analytically from slot `from` to slot
    /// `to`: whole entries complete at `t + ceil(total/μ)`; the entry at
    /// the boundary is partially consumed by whole slots only (a partial
    /// slot is never shared between jobs, eq. 2). Updates `progress`
    /// (remaining counts, last-finish, completion) as entries retire.
    pub fn drain(&mut self, jobs: &[Job], progress: &mut JobProgress, from: Slots, to: Slots) {
        let ServerQueues { queues, spare } = self;
        for (m, q) in queues.iter_mut().enumerate() {
            let mut t = from;
            let mut consumed = 0usize;
            for entry in q.iter_mut() {
                if t >= to {
                    break;
                }
                let mu = jobs[entry.job].mu[m];
                let slots = ceil_div(entry.total(), mu);
                if t + slots <= to {
                    // Entry fully processed at t + slots; its service
                    // began at the current cursor.
                    progress.note_start(entry.job, t);
                    t += slots;
                    for &(k, n) in &entry.parts {
                        progress.remaining[entry.job][k] -= n;
                        progress.total_remaining[entry.job] -= n;
                    }
                    progress.last_finish[entry.job] = progress.last_finish[entry.job].max(t);
                    if progress.total_remaining[entry.job] == 0
                        && progress.completion[entry.job].is_none()
                    {
                        progress.completion[entry.job] = Some(progress.last_finish[entry.job]);
                    }
                    consumed += 1;
                } else {
                    // Partial: (to − t) whole slots of this entry
                    // (t < to here, so at least one slot of progress).
                    progress.note_start(entry.job, t);
                    let mut budget = (to - t) * mu;
                    for (k, n) in entry.parts.iter_mut() {
                        let take = (*n).min(budget);
                        *n -= take;
                        progress.remaining[entry.job][*k] -= take;
                        progress.total_remaining[entry.job] -= take;
                        budget -= take;
                        if budget == 0 {
                            break;
                        }
                    }
                    entry.parts.retain(|&(_, n)| n > 0);
                    // The entry cannot have been exhausted: it needed more
                    // than (to − t) slots.
                    debug_assert!(entry.total() > 0);
                    break;
                }
            }
            for mut e in q.drain(..consumed) {
                e.parts.clear();
                spare.push(e.parts);
            }
        }
    }
}

impl EntrySink for ServerQueues {
    fn take_parts(&mut self) -> Vec<(usize, TaskCount)> {
        self.spare.pop().unwrap_or_default()
    }

    fn push_entry(&mut self, server: ServerId, job: usize, parts: Vec<(usize, TaskCount)>) {
        self.push(server, QueueEntry { job, parts });
    }
}

/// Pooled grouping workspace for the reordered engine's per-arrival queue
/// rebuild.
///
/// After every reorder, `run_reordered` turns each job's per-group
/// allocation into one [`QueueEntry`] per touched server. It used to do
/// that through a fresh `BTreeMap<ServerId, Vec<(usize, TaskCount)>>`
/// per job per arrival — the last per-arrival allocations of the
/// reordered engine. This workspace replaces the map with a per-server
/// **row pool** (`rows[m]` accumulates one job's `(group, tasks)` parts
/// for server `m`) plus a **touched-server list**, and pulls the entry
/// buffers it pushes into the queues from the [`ServerQueues`] spare
/// pool, so the steady-state rebuild touches the allocator zero times
/// (asserted by `rust/tests/alloc_stability.rs`).
///
/// Per-server queue contents are identical to the `BTreeMap` path: a job
/// contributes at most one entry per server, its parts appear in group
/// order, and the relative order of pushes to *different* servers never
/// affects any single server's FIFO.
#[derive(Clone, Debug, Default)]
pub struct QueueRebuild {
    /// `rows[m]`: the parts accumulated for server `m` by the job
    /// currently being grouped (cleared between jobs, capacity kept).
    rows: Vec<Vec<(usize, TaskCount)>>,
    /// Servers with a non-empty row, in first-touch order.
    touched: Vec<ServerId>,
    /// High-water parts-list length. Every buffer taken from the spare
    /// pool is reserved to this mark: recycled buffers re-pair with
    /// *different* entries on every arrival, so without the uniform
    /// reserve a small buffer meeting a large entry several arrivals
    /// after warmup would still grow — with it, every circulating buffer
    /// saturates within one recycle generation and the pooled footprint
    /// truly freezes.
    max_parts: usize,
}

impl QueueRebuild {
    pub fn new(num_servers: usize) -> Self {
        QueueRebuild {
            rows: vec![Vec::new(); num_servers],
            touched: Vec::new(),
            max_parts: 0,
        }
    }

    /// Group one job's per-group allocation by server and append the
    /// resulting entries to `sink` (a [`ServerQueues`] in the analytic
    /// reordered engine, the DES run queues in [`crate::des`]), recycling
    /// pooled buffers on both sides. `per_group[k]` lists `(server,
    /// tasks)` as produced by the assigners
    /// ([`crate::assign::Assignment::per_group`]).
    pub fn push_grouped<S: EntrySink>(
        &mut self,
        sink: &mut S,
        job: usize,
        per_group: &[Vec<(ServerId, TaskCount)>],
    ) {
        let QueueRebuild {
            rows,
            touched,
            max_parts,
        } = self;
        debug_assert!(touched.is_empty());
        for (k, alloc) in per_group.iter().enumerate() {
            for &(m, n) in alloc {
                if rows[m].is_empty() {
                    touched.push(m);
                }
                rows[m].push((k, n));
            }
        }
        for &m in touched.iter() {
            *max_parts = (*max_parts).max(rows[m].len());
            let mut parts = sink.take_parts();
            parts.reserve(*max_parts);
            parts.extend_from_slice(&rows[m]);
            sink.push_entry(m, job, parts);
            rows[m].clear();
        }
        touched.clear();
    }

    /// Reserved capacity across the pooled buffers (allocation-stability
    /// tests).
    pub fn footprint(&self) -> usize {
        self.rows.capacity()
            + self.rows.iter().map(|r| r.capacity()).sum::<usize>()
            + self.touched.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: usize, arrival: Slots, sizes: &[u64], servers: &[&[usize]], mu: Vec<u64>) -> Job {
        Job {
            id,
            arrival,
            groups: sizes
                .iter()
                .zip(servers)
                .map(|(&s, &sv)| TaskGroup::new(s, sv.to_vec()))
                .collect(),
            mu,
        }
    }

    #[test]
    fn observe_free_saturates() {
        let mut st = ClusterState::new(3);
        st.observe_free(&[10, 2, 7], 5);
        assert_eq!(st.busy(), &[5, 0, 2]);
        assert_eq!(st.num_servers(), 3);
    }

    #[test]
    fn reset_reuses_allocation() {
        let mut st = ClusterState::new(8);
        st.copy_from(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let cap = st.footprint();
        st.reset(8);
        assert_eq!(st.busy(), &[0; 8]);
        assert_eq!(st.footprint(), cap);
        st.reset(4);
        assert_eq!(st.num_servers(), 4);
        assert_eq!(st.footprint(), cap, "shrinking must not reallocate");
    }

    #[test]
    fn instance_view_borrows_busy() {
        let mut st = ClusterState::new(2);
        st.copy_from(&[3, 0]);
        let groups = vec![TaskGroup::new(4, vec![0, 1])];
        let mu = vec![1, 1];
        let inst = st.instance(&groups, &mu);
        assert_eq!(inst.busy, &[3, 0]);
        assert_eq!(inst.total_tasks(), 4);
    }

    #[test]
    fn drain_whole_and_partial_entries() {
        // Server 0, μ = 2: entry of 5 tasks = 3 slots.
        let jobs = vec![job(0, 0, &[5], &[&[0]], vec![2])];
        let mut progress = JobProgress::new(&jobs);
        let mut queues = ServerQueues::new(1);
        queues.push(
            0,
            QueueEntry {
                job: 0,
                parts: vec![(0, 5)],
            },
        );
        // Drain 2 of the 3 slots: 4 tasks consumed, 1 remains.
        queues.drain(&jobs, &mut progress, 0, 2);
        assert_eq!(progress.remaining[0], vec![1]);
        assert_eq!(progress.total_remaining[0], 1);
        assert!(progress.completion[0].is_none());
        // Drain the final slot: entry retires, job completes at 3.
        queues.drain(&jobs, &mut progress, 2, 3);
        assert_eq!(progress.total_remaining[0], 0);
        assert_eq!(progress.completion[0], Some(3));
        assert!(progress.all_complete());
    }

    #[test]
    fn queue_rebuild_matches_btreemap_grouping() {
        // The pooled rebuild must produce exactly the entries the old
        // per-arrival BTreeMap grouping produced: one entry per touched
        // server, parts in group order.
        let per_group: Vec<Vec<(ServerId, TaskCount)>> = vec![
            vec![(2, 5), (0, 1)],
            vec![(0, 3)],
            vec![(1, 7), (2, 2)],
        ];
        let mut queues = ServerQueues::new(4);
        let mut rebuild = QueueRebuild::new(4);
        rebuild.push_grouped(&mut queues, 9, &per_group);
        // Reference grouping via the old map-based path.
        let mut expect: std::collections::BTreeMap<ServerId, Vec<(usize, TaskCount)>> =
            Default::default();
        for (k, alloc) in per_group.iter().enumerate() {
            for &(m, n) in alloc {
                expect.entry(m).or_default().push((k, n));
            }
        }
        for (m, parts) in expect {
            let q = &queues.queues[m];
            assert_eq!(q.len(), 1, "server {m}");
            assert_eq!(q[0].job, 9);
            assert_eq!(q[0].parts, parts, "server {m}");
        }
        assert!(queues.queues[3].is_empty(), "untouched server stays empty");
    }

    #[test]
    fn queue_rebuild_pools_freeze_after_warmup() {
        // Cycling the same rebuild workload (including the drain/clear
        // retirement paths that refill the spare pool) must stop growing
        // capacity after the first full cycles.
        let jobs = vec![
            job(0, 0, &[6, 4], &[&[0, 1], &[2]], vec![2, 2, 2]),
            job(1, 0, &[5], &[&[1, 2]], vec![2, 2, 2]),
        ];
        let allocs: Vec<Vec<Vec<(ServerId, TaskCount)>>> = vec![
            // job 0: server 0 collects parts from both groups (multi-part
            // entry), servers 1 and 2 one part each.
            vec![vec![(0, 4), (1, 2)], vec![(0, 2), (2, 2)]],
            vec![vec![(1, 3), (2, 2)]],
        ];
        let mut queues = ServerQueues::new(3);
        let mut rebuild = QueueRebuild::new(3);
        let cycle = |queues: &mut ServerQueues, rebuild: &mut QueueRebuild| {
            let mut progress = JobProgress::new(&jobs);
            for (j, a) in allocs.iter().enumerate() {
                rebuild.push_grouped(queues, j, a);
            }
            // Retire some entries analytically, recycle the rest.
            queues.drain(&jobs, &mut progress, 0, 2);
            queues.clear();
        };
        // Two warmup cycles: the first grows fresh buffers, the second
        // lets the spare pool settle size-to-take pairings.
        cycle(&mut queues, &mut rebuild);
        cycle(&mut queues, &mut rebuild);
        let fp = queues.footprint() + rebuild.footprint();
        assert!(fp > 0, "warmup must have pooled buffers");
        for pass in 0..4 {
            cycle(&mut queues, &mut rebuild);
            assert_eq!(
                fp,
                queues.footprint() + rebuild.footprint(),
                "queue-rebuild pool grew on pass {pass}"
            );
        }
    }

    #[test]
    fn drained_entries_recycle_into_spare_pool() {
        let jobs = vec![job(0, 0, &[4], &[&[0]], vec![2])];
        let mut progress = JobProgress::new(&jobs);
        let mut queues = ServerQueues::new(1);
        let mut parts = queues.take_parts();
        assert!(parts.is_empty(), "fresh pool hands out empty buffers");
        parts.extend_from_slice(&[(0usize, 4u64)]);
        queues.push(0, QueueEntry { job: 0, parts });
        // Full retirement through drain recycles the buffer.
        queues.drain(&jobs, &mut progress, 0, 2);
        let recycled = queues.take_parts();
        assert!(recycled.is_empty() && recycled.capacity() >= 1);
    }

    #[test]
    fn drain_respects_fifo_order_per_server() {
        // Two entries on one μ=1 server: job 0 (2 tasks) then job 1
        // (2 tasks). Draining 3 slots finishes job 0 at 2 and eats one
        // task of job 1.
        let jobs = vec![
            job(0, 0, &[2], &[&[0]], vec![1]),
            job(1, 0, &[2], &[&[0]], vec![1]),
        ];
        let mut progress = JobProgress::new(&jobs);
        let mut queues = ServerQueues::new(1);
        queues.push(
            0,
            QueueEntry {
                job: 0,
                parts: vec![(0, 2)],
            },
        );
        queues.push(
            0,
            QueueEntry {
                job: 1,
                parts: vec![(0, 2)],
            },
        );
        queues.drain(&jobs, &mut progress, 0, 3);
        assert_eq!(progress.completion[0], Some(2));
        assert_eq!(progress.total_remaining[1], 1);
        assert!(progress.completion[1].is_none());
        // Latency decomposition: job 0 started at 0, job 1 at 2 (after
        // job 0's entry retired) — waits 0 and 2.
        assert_eq!(progress.first_start, vec![Some(0), Some(2)]);
        assert_eq!(progress.waits(&jobs), vec![0, 2]);
    }

    #[test]
    fn note_start_keeps_minimum() {
        let jobs = vec![job(0, 3, &[4], &[&[0]], vec![1])];
        let mut progress = JobProgress::new(&jobs);
        assert_eq!(progress.waits(&jobs), vec![0], "no start yet → wait 0");
        progress.note_start(0, 9);
        progress.note_start(0, 5);
        progress.note_start(0, 7);
        assert_eq!(progress.first_start[0], Some(5));
        assert_eq!(progress.waits(&jobs), vec![2]);
        assert_eq!(progress.waits_from(&[3]), vec![2]);
    }
}
