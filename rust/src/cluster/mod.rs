//! The cluster model: `M` servers holding replicated data chunks.
//!
//! Experiments don't materialize individual chunks — following the paper's
//! setup (§V-A), each task group's *available-server set* is drawn from the
//! Zipf placement model in [`placement`], and per-(server, job) computing
//! capacities `μ_m^c` are sampled uniformly from a configured range. The
//! live coordinator (`crate::coordinator`) does materialize chunk ownership
//! for its demo, using [`Cluster::chunk_holders`].

pub mod placement;
pub mod state;

use crate::config::ClusterConfig;
use crate::job::ServerId;
use crate::util::rng::Rng;

/// A distributed cluster of `m` servers.
#[derive(Clone, Debug)]
pub struct Cluster {
    cfg: ClusterConfig,
    /// Per-server speed factors (mean ≈ 1), non-empty only when
    /// `cfg.mu_skew > 0` (`hetero-cap` scenario): `μ_m^c` draws are
    /// multiplied by `speed[m]`, so a few servers are fast and the tail
    /// is slow.
    speed: Vec<f64>,
}

impl Cluster {
    /// Build a cluster from its configuration. (`generate` name kept for
    /// symmetry with `Trace::synth_alibaba`; placement state is sampled
    /// lazily per group.) With `mu_skew > 0` this draws the per-server
    /// speed profile from `rng`; the homogeneous default consumes no
    /// randomness, so historical seeds reproduce.
    pub fn generate(cfg: &ClusterConfig, rng: &mut Rng) -> Cluster {
        let speed = if cfg.mu_skew > 0.0 {
            // Zipf(s)-shaped factors over server ranks, normalized to
            // mean 1 so utilization calibration stays anchored, assigned
            // to servers in a random order.
            let mut raw: Vec<f64> = (1..=cfg.servers)
                .map(|rank| 1.0 / (rank as f64).powf(cfg.mu_skew))
                .collect();
            let mean = raw.iter().sum::<f64>() / cfg.servers as f64;
            for v in raw.iter_mut() {
                *v /= mean;
            }
            rng.shuffle(&mut raw);
            raw
        } else {
            Vec::new()
        };
        Cluster {
            cfg: cfg.clone(),
            speed,
        }
    }

    /// Per-server speed factors (empty for a homogeneous cluster).
    pub fn speed_profile(&self) -> &[f64] {
        &self.speed
    }

    pub fn num_servers(&self) -> usize {
        self.cfg.servers
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Sample the available-server set for one task group (paper §V-A):
    /// Zipf-ranked anchor over a random permutation, then `p` consecutive
    /// servers (wrapping), `p ~ U[avail_lo, avail_hi]`.
    pub fn sample_available(&self, placement: &placement::Placement, rng: &mut Rng) -> Vec<ServerId> {
        placement.sample_group_servers(rng, self.cfg.avail_lo, self.cfg.avail_hi)
    }

    /// Sample the per-server capacity vector `μ_·^c` for one job:
    /// uniform integer in `[mu_lo, mu_hi]` per server (paper §V-A default
    /// 3–5), scaled by the server's speed factor in a heterogeneous
    /// cluster (min 1 task/slot — a server never fully stalls).
    pub fn sample_mu(&self, rng: &mut Rng) -> Vec<u64> {
        (0..self.cfg.servers)
            .map(|m| {
                let base = rng.gen_range_incl(self.cfg.mu_lo, self.cfg.mu_hi);
                match self.speed.get(m) {
                    Some(&w) => ((base as f64 * w).round() as u64).max(1),
                    None => base,
                }
            })
            .collect()
    }

    /// Mean per-server capacity, used for utilization calibration: the
    /// exact expectation of what [`Cluster::sample_mu`] draws, including
    /// the per-draw rounding and min-1 clamp of the speed profile (a
    /// `max(base·w, 1)` shortcut underestimates slow-tail servers by up
    /// to ~15% and would bias the realized utilization of `hetero-cap`
    /// runs below the configured target).
    pub fn mean_mu(&self) -> f64 {
        if self.speed.is_empty() {
            return (self.cfg.mu_lo + self.cfg.mu_hi) as f64 / 2.0;
        }
        let n = (self.cfg.mu_hi - self.cfg.mu_lo + 1) as f64;
        self.speed
            .iter()
            .map(|&w| {
                (self.cfg.mu_lo..=self.cfg.mu_hi)
                    .map(|u| (u as f64 * w).round().max(1.0))
                    .sum::<f64>()
                    / n
            })
            .sum::<f64>()
            / self.speed.len() as f64
    }

    /// For the live coordinator: the set of servers holding a chunk,
    /// derived deterministically from the chunk id (consistent-hash-style
    /// ring walk with `replicas` copies).
    pub fn chunk_holders(&self, chunk_id: u64, replicas: usize) -> Vec<ServerId> {
        let m = self.cfg.servers;
        let replicas = replicas.min(m);
        // Mix the chunk id and walk the ring from the mixed anchor.
        let mut h = chunk_id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        h ^= h >> 32;
        let anchor = (h % m as u64) as usize;
        (0..replicas).map(|i| (anchor + i) % m).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn cluster() -> Cluster {
        Cluster::generate(&ClusterConfig::default(), &mut Rng::seed_from(1))
    }

    #[test]
    fn mu_within_configured_range() {
        let c = cluster();
        let mut rng = Rng::seed_from(2);
        for _ in 0..20 {
            let mu = c.sample_mu(&mut rng);
            assert_eq!(mu.len(), 100);
            assert!(mu.iter().all(|&x| (3..=5).contains(&x)));
        }
    }

    #[test]
    fn mean_mu_matches_range() {
        assert!((cluster().mean_mu() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn hetero_speed_profile_skews_mu() {
        let mut cfg = ClusterConfig::default();
        cfg.mu_skew = 1.0;
        let c = Cluster::generate(&cfg, &mut Rng::seed_from(40));
        let speed = c.speed_profile();
        assert_eq!(speed.len(), 100);
        // Normalized to mean ~1, with real spread.
        let mean: f64 = speed.iter().sum::<f64>() / 100.0;
        assert!((mean - 1.0).abs() < 1e-9, "mean {mean}");
        let max = speed.iter().cloned().fold(0.0, f64::max);
        let min = speed.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 10.0, "skew should spread speeds: {min}..{max}");
        // Sampled capacities stay >= 1 everywhere.
        let mut rng = Rng::seed_from(41);
        for _ in 0..10 {
            let mu = c.sample_mu(&mut rng);
            assert!(mu.iter().all(|&x| x >= 1));
            // The fast end must exceed the homogeneous ceiling somewhere.
            assert!(mu.iter().any(|&x| x > 5), "{mu:?}");
        }
        // Calibration mean reflects the clamped profile.
        assert!(c.mean_mu() > 0.9 && c.mean_mu() < 8.0, "{}", c.mean_mu());
    }

    #[test]
    fn homogeneous_cluster_consumes_no_rng() {
        // Cluster::generate must not disturb the shared RNG stream in the
        // default configuration (historical seeds reproduce).
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        let _ = Cluster::generate(&ClusterConfig::default(), &mut a);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn chunk_holders_distinct_and_in_range() {
        let c = cluster();
        for chunk in 0..50u64 {
            let holders = c.chunk_holders(chunk, 3);
            assert_eq!(holders.len(), 3);
            let mut dedup = holders.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), 3, "holders must be distinct");
            assert!(holders.iter().all(|&s| s < 100));
        }
    }

    #[test]
    fn chunk_holders_deterministic() {
        let c = cluster();
        assert_eq!(c.chunk_holders(7, 3), c.chunk_holders(7, 3));
    }

    #[test]
    fn chunk_holders_capped_at_cluster_size() {
        let mut cfg = ClusterConfig::default();
        cfg.servers = 2;
        cfg.avail_lo = 1;
        cfg.avail_hi = 2;
        let c = Cluster::generate(&cfg, &mut Rng::seed_from(3));
        assert_eq!(c.chunk_holders(1, 5).len(), 2);
    }
}
