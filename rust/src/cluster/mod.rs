//! The cluster model: `M` servers holding replicated data chunks.
//!
//! Experiments don't materialize individual chunks — following the paper's
//! setup (§V-A), each task group's *available-server set* is drawn from the
//! Zipf placement model in [`placement`], and per-(server, job) computing
//! capacities `μ_m^c` are sampled uniformly from a configured range. The
//! live coordinator (`crate::coordinator`) does materialize chunk ownership
//! for its demo, using [`Cluster::chunk_holders`].

pub mod placement;

use crate::config::ClusterConfig;
use crate::job::ServerId;
use crate::util::rng::Rng;

/// A distributed cluster of `m` servers.
#[derive(Clone, Debug)]
pub struct Cluster {
    cfg: ClusterConfig,
}

impl Cluster {
    /// Build a cluster from its configuration. (`generate` name kept for
    /// symmetry with `Trace::synth_alibaba`; placement state is sampled
    /// lazily per group.)
    pub fn generate(cfg: &ClusterConfig, _rng: &mut Rng) -> Cluster {
        Cluster { cfg: cfg.clone() }
    }

    pub fn num_servers(&self) -> usize {
        self.cfg.servers
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Sample the available-server set for one task group (paper §V-A):
    /// Zipf-ranked anchor over a random permutation, then `p` consecutive
    /// servers (wrapping), `p ~ U[avail_lo, avail_hi]`.
    pub fn sample_available(&self, placement: &placement::Placement, rng: &mut Rng) -> Vec<ServerId> {
        placement.sample_group_servers(rng, self.cfg.avail_lo, self.cfg.avail_hi)
    }

    /// Sample the per-server capacity vector `μ_·^c` for one job:
    /// uniform integer in `[mu_lo, mu_hi]` per server (paper §V-A default
    /// 3–5).
    pub fn sample_mu(&self, rng: &mut Rng) -> Vec<u64> {
        (0..self.cfg.servers)
            .map(|_| rng.gen_range_incl(self.cfg.mu_lo, self.cfg.mu_hi))
            .collect()
    }

    /// Mean per-server capacity, used for utilization calibration.
    pub fn mean_mu(&self) -> f64 {
        (self.cfg.mu_lo + self.cfg.mu_hi) as f64 / 2.0
    }

    /// For the live coordinator: the set of servers holding a chunk,
    /// derived deterministically from the chunk id (consistent-hash-style
    /// ring walk with `replicas` copies).
    pub fn chunk_holders(&self, chunk_id: u64, replicas: usize) -> Vec<ServerId> {
        let m = self.cfg.servers;
        let replicas = replicas.min(m);
        // Mix the chunk id and walk the ring from the mixed anchor.
        let mut h = chunk_id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        h ^= h >> 32;
        let anchor = (h % m as u64) as usize;
        (0..replicas).map(|i| (anchor + i) % m).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn cluster() -> Cluster {
        Cluster::generate(&ClusterConfig::default(), &mut Rng::seed_from(1))
    }

    #[test]
    fn mu_within_configured_range() {
        let c = cluster();
        let mut rng = Rng::seed_from(2);
        for _ in 0..20 {
            let mu = c.sample_mu(&mut rng);
            assert_eq!(mu.len(), 100);
            assert!(mu.iter().all(|&x| (3..=5).contains(&x)));
        }
    }

    #[test]
    fn mean_mu_matches_range() {
        assert!((cluster().mean_mu() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn chunk_holders_distinct_and_in_range() {
        let c = cluster();
        for chunk in 0..50u64 {
            let holders = c.chunk_holders(chunk, 3);
            assert_eq!(holders.len(), 3);
            let mut dedup = holders.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), 3, "holders must be distinct");
            assert!(holders.iter().all(|&s| s < 100));
        }
    }

    #[test]
    fn chunk_holders_deterministic() {
        let c = cluster();
        assert_eq!(c.chunk_holders(7, 3), c.chunk_holders(7, 3));
    }

    #[test]
    fn chunk_holders_capped_at_cluster_size() {
        let mut cfg = ClusterConfig::default();
        cfg.servers = 2;
        cfg.avail_lo = 1;
        cfg.avail_hi = 2;
        let c = Cluster::generate(&cfg, &mut Rng::seed_from(3));
        assert_eq!(c.chunk_holders(1, 5).len(), 2);
    }
}
