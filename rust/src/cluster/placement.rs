//! Zipf data-placement model (paper §V-A, "Available Servers").
//!
//! For each task group: draw a rank `i` from Zipf(α) over `1..=M`, map it
//! through a random permutation of the servers to get the *anchor* server
//! `m`, then the group's available servers are `m, m+1, …, m+p−1` (mod M)
//! with `p ~ U[p_lo, p_hi]`. α = 0 is the uniform distribution; α = 2 is
//! heavily skewed (hot servers attract most groups), which is where the
//! FIFO algorithms degrade and reordering shines (Figs 10–12).

use crate::job::ServerId;
use crate::util::rng::{Rng, Zipf};

/// Placement sampler for one experiment: a fixed permutation + Zipf CDF.
#[derive(Clone, Debug)]
pub struct Placement {
    perm: Vec<ServerId>,
    zipf: Zipf,
}

impl Placement {
    pub fn new(num_servers: usize, alpha: f64, rng: &mut Rng) -> Placement {
        assert!(num_servers > 0);
        let mut perm: Vec<ServerId> = (0..num_servers).collect();
        rng.shuffle(&mut perm);
        Placement {
            perm,
            zipf: Zipf::new(num_servers, alpha),
        }
    }

    pub fn num_servers(&self) -> usize {
        self.perm.len()
    }

    /// Sample the anchor server for one task group.
    pub fn sample_anchor(&self, rng: &mut Rng) -> ServerId {
        self.perm[self.zipf.sample(rng)]
    }

    /// Sample a full available-server set: anchor + the following `p−1`
    /// servers on the ring, `p ~ U[p_lo, p_hi]` (capped at M).
    pub fn sample_group_servers(&self, rng: &mut Rng, p_lo: usize, p_hi: usize) -> Vec<ServerId> {
        let m = self.perm.len();
        let p = rng.gen_range_incl(p_lo as u64, p_hi as u64) as usize;
        let p = p.min(m).max(1);
        let anchor = self.sample_anchor(rng);
        (0..p).map(|i| (anchor + i) % m).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn servers_contiguous_with_wrap() {
        let mut rng = Rng::seed_from(20);
        let pl = Placement::new(10, 1.0, &mut rng);
        for _ in 0..200 {
            let s = pl.sample_group_servers(&mut rng, 3, 5);
            assert!(s.len() >= 3 && s.len() <= 5);
            for w in s.windows(2) {
                assert_eq!((w[0] + 1) % 10, w[1], "contiguous ring walk: {s:?}");
            }
        }
    }

    #[test]
    fn alpha_zero_spreads_anchors_uniformly() {
        let mut rng = Rng::seed_from(21);
        let pl = Placement::new(10, 0.0, &mut rng);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[pl.sample_anchor(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 150.0, "{counts:?}");
        }
    }

    #[test]
    fn alpha_two_concentrates_anchors() {
        let mut rng = Rng::seed_from(22);
        let pl = Placement::new(100, 2.0, &mut rng);
        let mut counts = vec![0usize; 100];
        for _ in 0..10_000 {
            counts[pl.sample_anchor(&mut rng)] += 1;
        }
        let max = *counts.iter().max().unwrap();
        // Zipf(2) over 100 ranks gives p(rank 1) ≈ 0.61.
        assert!(max > 5000, "most-hit server got {max}/10000");
    }

    #[test]
    fn p_capped_at_cluster_size() {
        let mut rng = Rng::seed_from(23);
        let pl = Placement::new(4, 0.0, &mut rng);
        let s = pl.sample_group_servers(&mut rng, 8, 12);
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::seed_from(24);
        let mut r2 = Rng::seed_from(24);
        let p1 = Placement::new(20, 1.5, &mut r1);
        let p2 = Placement::new(20, 1.5, &mut r2);
        for _ in 0..20 {
            assert_eq!(
                p1.sample_group_servers(&mut r1, 2, 4),
                p2.sample_group_servers(&mut r2, 2, 4)
            );
        }
    }
}
