//! Zipf data-placement model (paper §V-A, "Available Servers").
//!
//! For each task group: draw a rank `i` from Zipf(α) over `1..=M`, map it
//! through a random permutation of the servers to get the *anchor* server
//! `m`. From the anchor, the available-server set is built in one of two
//! modes:
//!
//! - [`PlacementMode::Ring`] (the paper's model): `m, m+1, …, m+p−1`
//!   (mod M) with `p ~ U[p_lo, p_hi]`.
//! - [`PlacementMode::Scatter`] (the `hotspot` scenario): `p` *distinct*
//!   servers, each drawn independently through the Zipf anchor — the
//!   replica sets of different groups pile onto the same few hot servers
//!   instead of forming contiguous runs, modeling popularity-skewed
//!   replica placement.
//!
//! α = 0 is the uniform distribution; α = 2 is heavily skewed (hot
//! servers attract most groups), which is where the FIFO algorithms
//! degrade and reordering shines (Figs 10–12).

use crate::job::ServerId;
use crate::util::rng::{Rng, Zipf};

/// How a group's available-server set grows from its Zipf anchor.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlacementMode {
    /// Contiguous ring walk from the anchor (the paper's §V-A model).
    #[default]
    Ring,
    /// Independent Zipf draws per replica (hot-spot placement).
    Scatter,
}

impl PlacementMode {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementMode::Ring => "ring",
            PlacementMode::Scatter => "scatter",
        }
    }

    pub fn parse(s: &str) -> Option<PlacementMode> {
        match s.to_ascii_lowercase().as_str() {
            "ring" => Some(PlacementMode::Ring),
            "scatter" | "hotspot" => Some(PlacementMode::Scatter),
            _ => None,
        }
    }
}

/// Placement sampler for one experiment: a fixed permutation + Zipf CDF.
#[derive(Clone, Debug)]
pub struct Placement {
    perm: Vec<ServerId>,
    zipf: Zipf,
    mode: PlacementMode,
}

impl Placement {
    /// Ring-mode placement (the historical constructor; consumes the same
    /// RNG stream as ever, so existing seeds reproduce).
    pub fn new(num_servers: usize, alpha: f64, rng: &mut Rng) -> Placement {
        Placement::with_mode(num_servers, alpha, PlacementMode::Ring, rng)
    }

    pub fn with_mode(
        num_servers: usize,
        alpha: f64,
        mode: PlacementMode,
        rng: &mut Rng,
    ) -> Placement {
        assert!(num_servers > 0);
        let mut perm: Vec<ServerId> = (0..num_servers).collect();
        rng.shuffle(&mut perm);
        Placement {
            perm,
            zipf: Zipf::new(num_servers, alpha),
            mode,
        }
    }

    pub fn num_servers(&self) -> usize {
        self.perm.len()
    }

    pub fn mode(&self) -> PlacementMode {
        self.mode
    }

    /// Sample the anchor server for one task group.
    pub fn sample_anchor(&self, rng: &mut Rng) -> ServerId {
        self.perm[self.zipf.sample(rng)]
    }

    /// Sample a full available-server set of size `p ~ U[p_lo, p_hi]`
    /// (capped at M): a contiguous ring walk in [`PlacementMode::Ring`],
    /// `p` distinct Zipf-skewed servers in [`PlacementMode::Scatter`].
    pub fn sample_group_servers(&self, rng: &mut Rng, p_lo: usize, p_hi: usize) -> Vec<ServerId> {
        let m = self.perm.len();
        let p = rng.gen_range_incl(p_lo as u64, p_hi as u64) as usize;
        let p = p.min(m).max(1);
        match self.mode {
            PlacementMode::Ring => {
                let anchor = self.sample_anchor(rng);
                (0..p).map(|i| (anchor + i) % m).collect()
            }
            PlacementMode::Scatter => {
                let mut chosen = vec![false; m];
                let mut out = Vec::with_capacity(p);
                // Rejection-sample distinct servers through the Zipf
                // anchor. Under heavy skew the last few replicas of a
                // large set can take many retries, so after a bounded
                // number of attempts fall back to filling from the Zipf
                // rank order (deterministic, still hot-first).
                let mut attempts = 0;
                while out.len() < p && attempts < 32 * p {
                    attempts += 1;
                    let s = self.sample_anchor(rng);
                    if !chosen[s] {
                        chosen[s] = true;
                        out.push(s);
                    }
                }
                for &s in &self.perm {
                    if out.len() == p {
                        break;
                    }
                    if !chosen[s] {
                        chosen[s] = true;
                        out.push(s);
                    }
                }
                out.sort_unstable();
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn servers_contiguous_with_wrap() {
        let mut rng = Rng::seed_from(20);
        let pl = Placement::new(10, 1.0, &mut rng);
        for _ in 0..200 {
            let s = pl.sample_group_servers(&mut rng, 3, 5);
            assert!(s.len() >= 3 && s.len() <= 5);
            for w in s.windows(2) {
                assert_eq!((w[0] + 1) % 10, w[1], "contiguous ring walk: {s:?}");
            }
        }
    }

    #[test]
    fn alpha_zero_spreads_anchors_uniformly() {
        let mut rng = Rng::seed_from(21);
        let pl = Placement::new(10, 0.0, &mut rng);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[pl.sample_anchor(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 150.0, "{counts:?}");
        }
    }

    #[test]
    fn alpha_two_concentrates_anchors() {
        let mut rng = Rng::seed_from(22);
        let pl = Placement::new(100, 2.0, &mut rng);
        let mut counts = vec![0usize; 100];
        for _ in 0..10_000 {
            counts[pl.sample_anchor(&mut rng)] += 1;
        }
        let max = *counts.iter().max().unwrap();
        // Zipf(2) over 100 ranks gives p(rank 1) ≈ 0.61.
        assert!(max > 5000, "most-hit server got {max}/10000");
    }

    #[test]
    fn p_capped_at_cluster_size() {
        let mut rng = Rng::seed_from(23);
        let pl = Placement::new(4, 0.0, &mut rng);
        let s = pl.sample_group_servers(&mut rng, 8, 12);
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::seed_from(24);
        let mut r2 = Rng::seed_from(24);
        let p1 = Placement::new(20, 1.5, &mut r1);
        let p2 = Placement::new(20, 1.5, &mut r2);
        for _ in 0..20 {
            assert_eq!(
                p1.sample_group_servers(&mut r1, 2, 4),
                p2.sample_group_servers(&mut r2, 2, 4)
            );
        }
    }

    #[test]
    fn scatter_returns_distinct_in_range_servers() {
        let mut rng = Rng::seed_from(25);
        let pl = Placement::with_mode(30, 1.5, PlacementMode::Scatter, &mut rng);
        for _ in 0..300 {
            let s = pl.sample_group_servers(&mut rng, 3, 8);
            assert!(s.len() >= 3 && s.len() <= 8, "{s:?}");
            assert!(s.iter().all(|&x| x < 30));
            let mut dedup = s.clone();
            dedup.dedup(); // already sorted
            assert_eq!(dedup.len(), s.len(), "distinct servers: {s:?}");
        }
    }

    #[test]
    fn scatter_full_cluster_sets_terminate() {
        // p == M under heavy skew exercises the rank-order fallback.
        let mut rng = Rng::seed_from(26);
        let pl = Placement::with_mode(6, 2.0, PlacementMode::Scatter, &mut rng);
        for _ in 0..50 {
            let s = pl.sample_group_servers(&mut rng, 6, 6);
            assert_eq!(s, vec![0, 1, 2, 3, 4, 5]);
        }
    }

    #[test]
    fn scatter_concentrates_replicas_on_hot_servers() {
        let mut rng = Rng::seed_from(27);
        let pl = Placement::with_mode(50, 2.0, PlacementMode::Scatter, &mut rng);
        let mut counts = vec![0usize; 50];
        for _ in 0..2_000 {
            for s in pl.sample_group_servers(&mut rng, 3, 3) {
                counts[s] += 1;
            }
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let top3: usize = sorted[..3].iter().sum();
        let total: usize = sorted.iter().sum();
        assert!(
            top3 * 2 > total,
            "3 hottest servers should hold >50% of replicas: {top3}/{total}"
        );
    }

    #[test]
    fn placement_mode_parse_roundtrip() {
        for m in [PlacementMode::Ring, PlacementMode::Scatter] {
            assert_eq!(PlacementMode::parse(m.name()), Some(m));
        }
        assert_eq!(PlacementMode::parse("bogus"), None);
    }
}
