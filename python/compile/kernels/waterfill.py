"""L1 Pallas kernel: batched water-filling (paper SIII-B, Algorithm 2).

For each batch row (one candidate job against one cluster state), the
kernel runs the full WF recurrence over K task groups: the water level
xi_k is the minimal integer satisfying eq. (9),

    sum_m avail[k, m] * max(xi - busy[m], 0) * mu[m] >= sizes[k],

found by a fixed-iteration integer binary search (a masked reduce per
probe -- no sort needed, which is what makes this kernel a clean
data-parallel fit); busy times are then raised to the level (eq. 10) and
phi = max_k xi_k is the WF estimate the paper calls WF(I).

This is the inner loop of OCWF reordering (SIV): the rust coordinator
evaluates a whole batch of candidate jobs in one call.

TPU mapping (DESIGN.md SHardware-Adaptation): grid = B, one program per
batch row; the row's working set (busy[M], mu[M], avail[K,M], sizes[K])
lives in VMEM for all K groups; HBM traffic is one load + one store per
row. The kernel is VPU-bound (masked reduces), MXU-free by nature.

Padding contract: unused groups MUST have sizes[k] == 0 (the search then
converges to xi = 0 and the row state is untouched); unused servers MUST
have avail == 0 everywhere (mu/busy values are then irrelevant, but keep
mu >= 1 for hygiene). Rows are padded with all-zero sizes.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical and that is what the AOT artifacts
ship.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 31 probes decide any level < 2^31 and are a no-op fixpoint once the
# bracket collapses, so a static count is safe for all inputs.
_BS_ITERS = 31


def _wf_kernel(busy_ref, mu_ref, sizes_ref, avail_ref, phi_ref, busy_out_ref, *, K):
    """One batch row. Refs: busy/mu [1, M], sizes [1, K], avail [1, K, M];
    outputs phi [1], busy_out [1, M]."""
    busy = busy_ref[0, :].astype(jnp.int64)
    mu = mu_ref[0, :].astype(jnp.int64)
    sizes = sizes_ref[0, :].astype(jnp.int64)
    avail = avail_ref[0, :, :].astype(jnp.int64)

    def group_body(k, carry):
        busy, phi = carry
        size = sizes[k]
        mask = avail[k]
        # Feasible upper bracket: max masked busy + size (capacity grows by
        # at least one task per level once any masked server has mu >= 1).
        hi0 = jnp.max(jnp.where(mask > 0, busy, 0)) + size

        def probe(_, lohi):
            lo, hi = lohi
            mid = (lo + hi) // 2
            cap = jnp.sum(mask * jnp.maximum(mid - busy, 0) * mu)
            ok = cap >= size
            return (jnp.where(ok, lo, mid + 1), jnp.where(ok, mid, hi))

        _, xi = jax.lax.fori_loop(0, _BS_ITERS, probe, (jnp.int64(0), hi0))
        # eq. (10): participating servers (mask & busy < xi) rise to xi.
        busy = jnp.where((mask > 0) & (busy < xi), xi, busy)
        phi = jnp.maximum(phi, xi)
        return (busy, phi)

    busy, phi = jax.lax.fori_loop(0, K, group_body, (busy, jnp.int64(0)))
    phi_ref[0] = phi.astype(jnp.int32)
    busy_out_ref[0, :] = busy.astype(jnp.int32)


@partial(jax.jit, static_argnames=("interpret",))
def _noop(x, interpret=True):  # pragma: no cover - keeps jit cache warm in tests
    return x


def wf_phi_batch(busy, mu, sizes, avail, *, interpret=True):
    """Batched WF: busy/mu int32[B, M], sizes int32[B, K],
    avail int32[B, K, M] -> (phi int32[B], busy_out int32[B, M])."""
    b, m = busy.shape
    _, k = sizes.shape
    assert mu.shape == (b, m), mu.shape
    assert avail.shape == (b, k, m), avail.shape
    return pl.pallas_call(
        partial(_wf_kernel, K=k),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k, m), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b, m), jnp.int32),
        ],
        interpret=interpret,
    )(busy, mu, sizes, avail)
