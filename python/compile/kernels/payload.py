"""L1 Pallas kernel: the per-task data-chunk compute payload.

Each task in the live coordinator reads one data chunk (a row of D
features) and reduces it through a small nonlinear transform:

    y[i] = sum_f tanh(x[i] @ W)[f]^2

The matmul is the MXU-shaped part (tiled by BlockSpec over the task
batch), the tanh/square/row-sum epilogue is VPU work. W is a fixed,
deterministic projection baked into the artifact at AOT time, so the rust
request path only ships chunk rows.

TPU mapping: grid over N/block_n row tiles; each program holds an
(block_n, D) x tile and the full (D, F) W panel in VMEM -- at the shipped
sizes (D=32, F=16) W is 2 KiB and the schedule is a single pass over x.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _payload_kernel(x_ref, w_ref, y_ref):
    x = x_ref[...]
    w = w_ref[...]
    h = jnp.tanh(jnp.dot(x, w, preferred_element_type=jnp.float32))
    y_ref[...] = jnp.sum(h * h, axis=1)


def chunk_payload(x, w, *, block_n=None, interpret=True):
    """x f32[N, D], w f32[D, F] -> y f32[N]. N must be divisible by
    block_n (default: min(64, N))."""
    n, d = x.shape
    d2, f = w.shape
    assert d == d2, (x.shape, w.shape)
    if block_n is None:
        block_n = min(64, n)
    assert n % block_n == 0, (n, block_n)
    return pl.pallas_call(
        _payload_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(x, w)


def fixed_projection(d, f, seed=0x7A05):
    """The deterministic W baked into the payload artifact: a cheap
    hash-like construction that is stable across jax versions (no RNG
    implementation dependence)."""
    i = jnp.arange(d, dtype=jnp.float32)[:, None]
    j = jnp.arange(f, dtype=jnp.float32)[None, :]
    s = jnp.float32(seed % 1000) / 1000.0
    return jnp.sin(i * 12.9898 + j * 78.233 + s) * 0.43


@partial(jax.jit, static_argnames=("d", "f"))
def payload_fixed(x, *, d, f):
    """The AOT entrypoint: payload with the baked projection."""
    w = fixed_projection(d, f)
    return chunk_payload(x, w)
