"""Pure-numpy correctness oracles for the Pallas kernels.

These mirror the rust-native implementations (`rust/src/assign/wf.rs`)
line for line: the L3 <-> L1 agreement test (`taos verify-kernel`) and the
pytest suites both anchor on this file.
"""

import numpy as np


def water_level_ref(servers_mask, size, busy, mu):
    """Minimal integer xi with sum(mask * max(xi - busy, 0) * mu) >= size.
    Mirrors `assign::bounds::water_level` (sort-free binary search)."""
    size = int(size)
    if size == 0:
        return 0
    busy = np.asarray(busy, dtype=np.int64)
    mu = np.asarray(mu, dtype=np.int64)
    mask = np.asarray(servers_mask, dtype=np.int64)
    assert mask.any(), "group with no available servers"

    def cap(x):
        return int(np.sum(mask * np.maximum(x - busy, 0) * mu))

    lo, hi = 1, int(np.max(busy * mask)) + size
    assert cap(hi) >= size
    while lo < hi:
        mid = (lo + hi) // 2
        if cap(mid) >= size:
            hi = mid
        else:
            lo = mid + 1
    return hi


def wf_phi_ref(busy, mu, sizes, avail):
    """Reference batched WF.

    busy, mu: int[B, M]; sizes: int[B, K]; avail: int[B, K, M].
    Returns (phi int64[B], busy_out int64[B, M]).
    """
    busy = np.asarray(busy, dtype=np.int64).copy()
    mu = np.asarray(mu, dtype=np.int64)
    sizes = np.asarray(sizes, dtype=np.int64)
    avail = np.asarray(avail, dtype=np.int64)
    b, m = busy.shape
    _, k = sizes.shape
    phi = np.zeros(b, dtype=np.int64)
    for row in range(b):
        for g in range(k):
            size = sizes[row, g]
            if size == 0:
                continue
            mask = avail[row, g]
            xi = water_level_ref(mask, size, busy[row], mu[row])
            participating = (mask > 0) & (busy[row] < xi)
            busy[row][participating] = xi
            phi[row] = max(phi[row], xi)
    return phi, busy


def payload_ref(x, w):
    """Reference payload: y[i] = sum_f tanh(x[i] @ w)[f]^2 in float64
    (tight tolerance target for the f32 kernel)."""
    x = np.asarray(x, dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    h = np.tanh(x @ w)
    return np.sum(h * h, axis=1)
