"""L2: the jitted computation graphs that get AOT-lowered to HLO text.

Two entrypoints, both calling L1 Pallas kernels (interpret=True so the
lowered HLO is plain ops the CPU PJRT plugin can run):

- ``wf_phi_model``  -- batched water-filling evaluation, the inner loop of
  OCWF reordering (paper SIV). Inputs are padded to the static (B, K, M)
  of the artifact; see the padding contract in ``kernels/waterfill.py``.
- ``payload_model`` -- the per-task chunk payload with the projection
  baked in; the live request path ships only chunk rows.

`jax_enable_x64` must be on (aot.py and conftest.py set it): the water
level search accumulates capacities in int64.
"""

import jax
import jax.numpy as jnp

from .kernels.payload import chunk_payload, fixed_projection
from .kernels.waterfill import wf_phi_batch


def wf_phi_model(busy, mu, sizes, avail):
    """int32[B,M], int32[B,M], int32[B,K], int32[B,K,M] ->
    (phi int32[B], busy_out int32[B,M])."""
    phi, busy_out = wf_phi_batch(busy, mu, sizes, avail)
    return phi, busy_out


def payload_model(x):
    """f32[N, D] -> f32[N], with the fixed projection (D -> F = D // 2)."""
    n, d = x.shape
    w = fixed_projection(d, max(d // 2, 1))
    return (chunk_payload(x, w),)


def wf_phi_lowered(b, k, m):
    """Lower wf_phi_model at static shape (B=b, K=k, M=m)."""
    spec_bm = jax.ShapeDtypeStruct((b, m), jnp.int32)
    spec_bk = jax.ShapeDtypeStruct((b, k), jnp.int32)
    spec_bkm = jax.ShapeDtypeStruct((b, k, m), jnp.int32)
    return jax.jit(wf_phi_model).lower(spec_bm, spec_bm, spec_bk, spec_bkm)


def payload_lowered(n, d):
    """Lower payload_model at static shape (N=n, D=d)."""
    spec = jax.ShapeDtypeStruct((n, d), jnp.float32)
    return jax.jit(payload_model).lower(spec)
