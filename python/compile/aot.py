"""AOT lowering: jax -> HLO *text* -> artifacts/ for the rust runtime.

HLO text, not serialized HloModuleProto: jax >= 0.5 emits protos with
64-bit instruction ids which the `xla` crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. Lowered with return_tuple=True; the rust side
unwraps with `Literal::to_tuple`.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
`artifacts` target). Writes one .hlo.txt per artifact plus manifest.json
describing the static shapes, which `runtime::ArtifactIndex` consumes.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)  # int64 capacity sums in the WF kernel

from jax._src.lib import xla_client as xc  # noqa: E402

from .model import payload_lowered, wf_phi_lowered  # noqa: E402

# Static artifact shapes. `wf_phi` is sized for the reorder batches the
# coordinator sends (and the verify-kernel harness); `payload` for the
# live demo's task batches.
ARTIFACTS = {
    "wf_phi": dict(B=8, K=8, M=32),
    "wf_phi_large": dict(B=32, K=16, M=128),
    "payload": dict(N=64, D=32),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name: str, params: dict):
    if name.startswith("wf_phi"):
        return wf_phi_lowered(params["B"], params["K"], params["M"])
    if name == "payload":
        return payload_lowered(params["N"], params["D"])
    raise ValueError(f"unknown artifact {name}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", nargs="*", default=None, help="subset of artifact names"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, params in ARTIFACTS.items():
        if args.only and name not in args.only:
            continue
        lowered = lower_one(name, params)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {"file": fname, "params": params}
        print(f"wrote {path} ({len(text)} chars) params={params}")

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
