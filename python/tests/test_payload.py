"""Pallas payload kernel vs the float64 numpy oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.payload import chunk_payload, fixed_projection, payload_fixed
from compile.kernels.ref import payload_ref


def test_known_zero_input():
    x = np.zeros((4, 8), np.float32)
    w = fixed_projection(8, 4)
    y = np.asarray(chunk_payload(x, w))
    np.testing.assert_allclose(y, 0.0, atol=1e-7)


def test_matches_ref_basic():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, 16)).astype(np.float32)
    w = rng.normal(size=(16, 4)).astype(np.float32)
    y = np.asarray(chunk_payload(x, w))
    np.testing.assert_allclose(y, payload_ref(x, w), rtol=1e-5, atol=1e-5)


def test_blocking_invariance():
    """The BlockSpec tiling must not change the numbers."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(32, 8)).astype(np.float32)
    w = rng.normal(size=(8, 4)).astype(np.float32)
    y_full = np.asarray(chunk_payload(x, w, block_n=32))
    y_tiled = np.asarray(chunk_payload(x, w, block_n=8))
    np.testing.assert_allclose(y_full, y_tiled, rtol=1e-6, atol=1e-6)


def test_payload_fixed_entrypoint():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(64, 32)).astype(np.float32)
    y = payload_fixed(x, d=32, f=16)
    w = np.asarray(fixed_projection(32, 16))
    np.testing.assert_allclose(np.asarray(y), payload_ref(x, w), rtol=1e-4, atol=1e-5)


def test_fixed_projection_deterministic():
    a = np.asarray(fixed_projection(16, 8))
    b = np.asarray(fixed_projection(16, 8))
    np.testing.assert_array_equal(a, b)
    assert np.abs(a).max() <= 0.43 + 1e-6


@settings(max_examples=40, deadline=None)
@given(
    st.sampled_from([(4, 4, 2), (8, 16, 4), (16, 8, 8), (64, 32, 16)]),
    st.integers(0, 2**31 - 1),
)
def test_matches_ref_across_shapes(shape, seed):
    n, d, f = shape
    rng = np.random.default_rng(seed)
    x = rng.uniform(-3, 3, size=(n, d)).astype(np.float32)
    w = rng.uniform(-1, 1, size=(d, f)).astype(np.float32)
    y = np.asarray(chunk_payload(x, w))
    np.testing.assert_allclose(y, payload_ref(x, w), rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.floats(-50, 50, allow_nan=False))
def test_saturation_bounded(scale):
    """tanh^2 <= 1, so y <= F regardless of input magnitude."""
    x = np.full((4, 8), np.float32(scale))
    w = np.asarray(fixed_projection(8, 4))
    y = np.asarray(chunk_payload(x, w))
    assert (y <= 4.0 + 1e-5).all()
    assert (y >= 0.0).all()
