"""Pallas water-filling kernel vs the pure-numpy oracle.

The oracle (`ref.wf_phi_ref`) mirrors rust's `assign::wf`; the kernel must
agree *exactly* (integer semantics) on every instance, including the
padding contract (zero-size groups / all-zero availability rows).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import water_level_ref, wf_phi_ref
from compile.kernels.waterfill import wf_phi_batch


def run_kernel(busy, mu, sizes, avail):
    phi, busy_out = wf_phi_batch(
        np.asarray(busy, np.int32),
        np.asarray(mu, np.int32),
        np.asarray(sizes, np.int32),
        np.asarray(avail, np.int32),
    )
    return np.asarray(phi, np.int64), np.asarray(busy_out, np.int64)


def assert_matches_ref(busy, mu, sizes, avail):
    phi_k, busy_k = run_kernel(busy, mu, sizes, avail)
    phi_r, busy_r = wf_phi_ref(busy, mu, sizes, avail)
    np.testing.assert_array_equal(phi_k, phi_r)
    np.testing.assert_array_equal(busy_k, busy_r)


# ---------- directed cases ----------


def test_single_group_idle_servers():
    # 12 tasks over 3 idle servers with mu=2 -> level 2, phi 2.
    busy = [[0, 0, 0]]
    mu = [[2, 2, 2]]
    sizes = [[12]]
    avail = [[[1, 1, 1]]]
    phi, busy_out = run_kernel(busy, mu, sizes, avail)
    assert phi.tolist() == [2]
    assert busy_out.tolist() == [[2, 2, 2]]


def test_busy_server_excluded():
    busy = [[10, 0]]
    mu = [[1, 1]]
    sizes = [[4]]
    avail = [[[1, 1]]]
    phi, busy_out = run_kernel(busy, mu, sizes, avail)
    assert phi.tolist() == [4]
    assert busy_out.tolist() == [[10, 4]]


def test_sequential_groups_stack():
    # Mirrors the rust unit test `sequential_groups_stack`.
    busy = [[0, 0, 0]]
    mu = [[1, 1, 1]]
    sizes = [[4, 4]]
    avail = [[[1, 1, 0], [0, 1, 1]]]
    phi, busy_out = run_kernel(busy, mu, sizes, avail)
    assert phi.tolist() == [3]
    assert busy_out.tolist() == [[2, 3, 3]]


def test_zero_size_groups_are_noops():
    busy = [[3, 1]]
    mu = [[2, 2]]
    sizes = [[0, 6, 0]]
    avail = [[[1, 1], [1, 1], [0, 0]]]
    phi, busy_out = run_kernel(busy, mu, sizes, avail)
    # Level for the middle group: busy (3,1): xi=3 -> (0 + 2*2)=4 < 6;
    # xi=4 -> (1*2 + 3*2) = 8 >= 6 -> xi 4.
    assert phi.tolist() == [4]
    assert busy_out.tolist() == [[4, 4]]


def test_fully_padded_row():
    busy = [[0, 0], [5, 7]]
    mu = [[1, 1], [2, 2]]
    sizes = [[3], [0]]
    avail = [[[1, 1]], [[0, 0]]]
    phi, busy_out = run_kernel(busy, mu, sizes, avail)
    assert phi[1] == 0
    assert busy_out[1].tolist() == [5, 7]


def test_theorem1_construction():
    # K=3, theta=3: WF phi must be K*theta = 9.
    theta, K = 3, 3
    sizes_per_group = [sum(theta**e for e in range(1, K - k + 2)) for k in range(K)]
    m = sizes_per_group[0]
    avail = np.zeros((1, K, m), np.int32)
    sizes = np.zeros((1, K), np.int32)
    for k in range(K):
        avail[0, k, : sizes_per_group[k]] = 1
        sizes[0, k] = theta * sizes_per_group[k]
    busy = np.zeros((1, m), np.int32)
    mu = np.ones((1, m), np.int32)
    phi, _ = run_kernel(busy, mu, sizes, avail)
    assert phi.tolist() == [K * theta]
    assert_matches_ref(busy, mu, sizes, avail)


def test_saturated_single_server():
    busy = [[7]]
    mu = [[3]]
    sizes = [[10]]
    avail = [[[1]]]
    phi, _ = run_kernel(busy, mu, sizes, avail)
    assert phi.tolist() == [7 + 4]  # ceil(10/3) past the backlog


def test_large_values_no_overflow():
    # Capacity sums cross 2^31 during early probes; int64 internals must
    # keep the result exact.
    busy = [[1_000_000, 0]]
    mu = [[7, 7]]
    sizes = [[2_000_000]]
    avail = [[[1, 1]]]
    assert_matches_ref(busy, mu, sizes, avail)


def test_water_level_ref_minimal():
    # The oracle's own invariant, spot-checked.
    assert water_level_ref([1, 1], 5, [0, 0], [2, 3]) == 1
    assert water_level_ref([1, 1], 6, [0, 0], [2, 3]) == 2
    assert water_level_ref([1, 0], 6, [0, 99], [2, 3]) == 3


# ---------- hypothesis sweeps ----------

instances = st.integers(1, 4).flatmap(
    lambda b: st.integers(1, 4).flatmap(
        lambda k: st.integers(1, 6).flatmap(
            lambda m: st.tuples(
                st.just((b, k, m)),
                st.lists(
                    st.integers(0, 40), min_size=b * m, max_size=b * m
                ),  # busy
                st.lists(st.integers(1, 5), min_size=b * m, max_size=b * m),  # mu
                st.lists(st.integers(0, 60), min_size=b * k, max_size=b * k),  # sizes
                st.lists(
                    st.integers(0, 1), min_size=b * k * m, max_size=b * k * m
                ),  # avail
            )
        )
    )
)


@settings(max_examples=60, deadline=None)
@given(instances)
def test_kernel_matches_ref_on_random_instances(data):
    (b, k, m), busy, mu, sizes, avail = data
    busy = np.array(busy, np.int32).reshape(b, m)
    mu = np.array(mu, np.int32).reshape(b, m)
    sizes = np.array(sizes, np.int32).reshape(b, k)
    avail = np.array(avail, np.int32).reshape(b, k, m)
    # Padding contract: a group with no available servers must be empty.
    for row in range(b):
        for g in range(k):
            if avail[row, g].sum() == 0:
                sizes[row, g] = 0
    assert_matches_ref(busy, mu, sizes, avail)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 3),
    st.integers(1, 8),
    st.lists(st.integers(0, 30), min_size=1, max_size=8),
)
def test_busy_monotone_nondecreasing(b_rows, m, sizes_list):
    """Water-filling never lowers a busy time (eq. 8)."""
    k = len(sizes_list)
    rng = np.random.default_rng(42)
    busy = rng.integers(0, 20, size=(b_rows, m)).astype(np.int32)
    mu = rng.integers(1, 5, size=(b_rows, m)).astype(np.int32)
    sizes = np.tile(np.array(sizes_list, np.int32), (b_rows, 1))
    avail = rng.integers(0, 2, size=(b_rows, k, m)).astype(np.int32)
    for row in range(b_rows):
        for g in range(k):
            if avail[row, g].sum() == 0:
                avail[row, g, 0] = 1
    _, busy_out = run_kernel(busy, mu, sizes, avail)
    assert (busy_out >= busy).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**20), st.integers(1, 7))
def test_single_server_level_is_ceil(size, mu_v):
    busy = [[0]]
    mu = [[mu_v]]
    sizes = [[size]]
    avail = [[[1]]]
    phi, _ = run_kernel(busy, mu, sizes, avail)
    assert phi[0] == -(-size // mu_v)  # ceil division


@pytest.mark.parametrize("dtype", [np.int32])
def test_dtype_contract(dtype):
    # The artifact interface is int32-in/int32-out.
    phi, busy_out = wf_phi_batch(
        np.zeros((1, 2), dtype),
        np.ones((1, 2), dtype),
        np.full((1, 1), 4, dtype),
        np.ones((1, 1, 2), dtype),
    )
    assert phi.dtype == np.int32
    assert busy_out.dtype == np.int32
