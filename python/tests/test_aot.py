"""AOT lowering tests: the HLO-text artifacts must lower, carry the
expected entry signature, and evaluate (via jax) to the same numbers as
the eager kernels."""

import json
import os
import subprocess
import sys

import numpy as np

from compile.aot import ARTIFACTS, lower_one, to_hlo_text
from compile.kernels.ref import wf_phi_ref
from compile.model import payload_lowered, wf_phi_lowered


def test_wf_phi_lowers_to_hlo_text():
    text = to_hlo_text(wf_phi_lowered(2, 3, 4))
    assert "HloModule" in text
    # Entry params: 4 int32 tensors.
    assert "s32[2,4]" in text
    assert "s32[2,3,4]" in text


def test_payload_lowers_to_hlo_text():
    text = to_hlo_text(payload_lowered(8, 4))
    assert "HloModule" in text
    assert "f32[8,4]" in text
    assert "tanh" in text.lower()


def test_lowered_wf_executes_like_eager():
    lowered = wf_phi_lowered(2, 2, 3)
    compiled = lowered.compile()
    busy = np.array([[0, 1, 2], [3, 0, 0]], np.int32)
    mu = np.array([[1, 2, 1], [1, 1, 1]], np.int32)
    sizes = np.array([[5, 2], [4, 0]], np.int32)
    avail = np.array(
        [[[1, 1, 0], [0, 1, 1]], [[1, 1, 1], [0, 0, 0]]], np.int32
    )
    phi, busy_out = compiled(busy, mu, sizes, avail)
    phi_r, busy_r = wf_phi_ref(busy, mu, sizes, avail)
    np.testing.assert_array_equal(np.asarray(phi, np.int64), phi_r)
    np.testing.assert_array_equal(np.asarray(busy_out, np.int64), busy_r)


def test_all_registered_artifacts_lower():
    for name, params in ARTIFACTS.items():
        text = to_hlo_text(lower_one(name, params))
        assert "HloModule" in text, name
        assert len(text) > 500, name


def test_aot_cli_writes_manifest(tmp_path):
    """End-to-end: the module CLI writes artifacts + manifest (small
    subset to keep the test fast)."""
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    repo_python = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--only",
            "payload",
        ],
        check=True,
        cwd=repo_python,
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["payload"]["file"] == "payload.hlo.txt"
    assert (out / "payload.hlo.txt").exists()
    assert manifest["payload"]["params"]["N"] == ARTIFACTS["payload"]["N"]
