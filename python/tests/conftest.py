import jax

# The water-filling kernel accumulates level capacities in int64; enable
# x64 before any kernel module is imported (aot.py does the same).
jax.config.update("jax_enable_x64", True)
