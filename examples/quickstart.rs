//! Quickstart: build a small cluster + trace, run FIFO water-filling and
//! OCWF-ACC, and compare completion times.
//!
//! ```text
//! cargo run --release --offline --example quickstart
//! ```

use taos::prelude::*;

fn main() {
    // A scaled-down version of the paper's setup (§V-A): Zipf-placed task
    // groups, per-(server, job) capacities in [3, 5].
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.servers = 30;
    cfg.cluster.zipf_alpha = 1.0;
    cfg.cluster.avail_lo = 4;
    cfg.cluster.avail_hi = 8;
    cfg.trace.jobs = 60;
    cfg.trace.total_tasks = 6_000;
    cfg.trace.utilization = 0.6;
    cfg.seed = 7;

    println!("cluster: {} servers, zipf alpha {}", cfg.cluster.servers, cfg.cluster.zipf_alpha);
    println!(
        "trace  : {} jobs, {} tasks, {:.0}% utilization\n",
        cfg.trace.jobs,
        cfg.trace.total_tasks,
        cfg.trace.utilization * 100.0
    );

    for policy in [
        SchedPolicy::fifo(AssignPolicy::Wf),
        SchedPolicy::fifo(AssignPolicy::Obta),
        SchedPolicy::ocwf(true),
    ] {
        let out = taos::sim::run_experiment(&cfg, policy).expect("run");
        let s = out.jct_stats();
        println!(
            "{:<9} mean JCT {:>7.1}  p99 {:>7.0}  makespan {:>6}  overhead {:>8.1} us/arrival",
            policy.name(),
            s.mean,
            s.p99,
            out.makespan,
            out.overhead.mean_us()
        );
    }
    println!("\n(see `taos repro --fig 12 --quick` for the full six-way comparison)");
}
