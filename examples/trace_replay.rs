//! End-to-end driver: replay the full Alibaba-like trace (250 jobs,
//! 113,653 tasks — the paper's workload scale) through all six
//! algorithms and report the paper's headline metrics: average job
//! completion time and per-arrival computation overhead.
//!
//! ```text
//! cargo run --release --offline --example trace_replay            # paper scale
//! cargo run --release --offline --example trace_replay -- --quick # CI scale
//! ```
//!
//! The run is recorded in EXPERIMENTS.md (§End-to-end).

use taos::benchlib::TextTable;
use taos::prelude::*;
use taos::util::json::Json;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick {
        taos::sweep::quick_base(42)
    } else {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.zipf_alpha = 1.0;
        cfg.trace.utilization = 0.5;
        cfg.seed = 42;
        cfg
    };
    println!(
        "replaying {} jobs / {} tasks on {} servers (alpha {}, {:.0}% util)\n",
        cfg.trace.jobs,
        cfg.trace.total_tasks,
        cfg.cluster.servers,
        cfg.cluster.zipf_alpha,
        cfg.trace.utilization * 100.0
    );

    let mut table = TextTable::new(&[
        "algorithm",
        "mean JCT",
        "p50",
        "p99",
        "makespan",
        "overhead us",
    ]);
    let mut rows = Vec::new();
    for policy in SchedPolicy::ALL {
        let t0 = std::time::Instant::now();
        let out = taos::sim::run_experiment(&cfg, policy).expect("run");
        let s = out.jct_stats();
        eprintln!(
            "  {} done in {:.1}s (overhead {:.1} us/arrival)",
            policy.name(),
            t0.elapsed().as_secs_f64(),
            out.overhead.mean_us()
        );
        table.row(vec![
            policy.name().into(),
            format!("{:.0}", s.mean),
            format!("{:.0}", s.p50),
            format!("{:.0}", s.p99),
            format!("{}", out.makespan),
            format!("{:.1}", out.overhead.mean_us()),
        ]);
        rows.push(Json::obj(vec![
            ("algorithm", Json::str(policy.name())),
            ("mean_jct", Json::num(s.mean)),
            ("p99_jct", Json::num(s.p99)),
            ("overhead_us", Json::num(out.overhead.mean_us())),
        ]));
    }
    println!("\n{}", table.render());
    let out_path = "trace_replay_results.json";
    std::fs::write(out_path, Json::arr(rows).to_string()).expect("write results");
    println!("wrote {out_path}");
}
