//! Reordering ablation (paper §IV): how much does shortest-estimated-
//! time-first reordering help as data-placement skew grows, and how much
//! computation does the early-exit technique save?
//!
//! ```text
//! cargo run --release --offline --example reorder_study
//! ```

use taos::benchlib::TextTable;
use taos::prelude::*;

fn main() {
    let mut base = taos::sweep::quick_base(21);
    base.trace.utilization = 0.75;

    println!("== mean JCT: FIFO WF vs OCWF vs OCWF-ACC, rising skew ==\n");
    let mut t = TextTable::new(&["alpha", "wf (fifo)", "ocwf", "ocwf-acc", "jct gain", "wf evals ocwf", "wf evals acc", "evals saved"]);
    for &alpha in &[0.0, 0.5, 1.0, 1.5, 2.0] {
        let mut cfg = base.clone();
        cfg.cluster.zipf_alpha = alpha;
        let fifo = taos::sim::run_experiment(&cfg, SchedPolicy::fifo(AssignPolicy::Wf)).unwrap();
        let ocwf = taos::sim::run_experiment(&cfg, SchedPolicy::ocwf(false)).unwrap();
        let acc = taos::sim::run_experiment(&cfg, SchedPolicy::ocwf(true)).unwrap();
        assert_eq!(
            ocwf.jcts, acc.jcts,
            "OCWF and OCWF-ACC must produce identical schedules"
        );
        t.row(vec![
            format!("{alpha}"),
            format!("{:.0}", fifo.mean_jct()),
            format!("{:.0}", ocwf.mean_jct()),
            format!("{:.0}", acc.mean_jct()),
            format!("{:.1}x", fifo.mean_jct() / ocwf.mean_jct().max(1e-9)),
            format!("{}", ocwf.wf_evals),
            format!("{}", acc.wf_evals),
            format!(
                "{:.0}%",
                100.0 * (1.0 - acc.wf_evals as f64 / ocwf.wf_evals.max(1) as f64)
            ),
        ]);
    }
    println!("{}", t.render());
    println!("The paper's two §IV claims, reproduced:");
    println!("  1. reordering is robust to skew (OCWF JCT flat while FIFO WF degrades),");
    println!("  2. early-exit cuts the reordering computation (fewer WF evaluations)");
    println!("     while producing the exact same schedule.");
}
