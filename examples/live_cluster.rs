//! Live-mode example: the rust coordinator executing *real* compute —
//! every task runs the AOT-compiled Pallas payload kernel through the
//! PJRT CPU client (no Python anywhere on the request path).
//!
//! ```text
//! make artifacts
//! cargo run --release --offline --example live_cluster
//! ```
//!
//! Prints per-job latency and task throughput, and cross-checks the
//! batched water-filling kernel against the native rust WF.

use std::path::Path;
use std::sync::Arc;

use taos::assign::AssignPolicy;
use taos::cluster::Cluster;
use taos::config::ClusterConfig;
use taos::coordinator::{verify, AccelHandle, Leader, LiveJobSpec};
use taos::util::rng::Rng;

fn main() {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // 1. Cross-layer check: AOT kernel == native WF on 32 random
    //    instances.
    let (checked, batch) =
        verify::verify_wf_kernel(artifacts, 32, 7).expect("kernel verification");
    println!("[verify] AOT water-filling kernel == native WF on {checked} instances (batch {batch})\n");

    // 2. Live cluster: 4 worker servers, chunked data with 3-way
    //    replication, WF assignment over live queue depths.
    let accel = Arc::new(AccelHandle::spawn(artifacts).expect("accelerator"));
    let mut ccfg = ClusterConfig::default();
    ccfg.servers = 4;
    ccfg.avail_lo = 1;
    ccfg.avail_hi = 3;
    let cluster = Cluster::generate(&ccfg, &mut Rng::seed_from(1));
    let leader = Leader::start(cluster, Arc::clone(&accel), 3).expect("leader");

    let mut rng = Rng::seed_from(99);
    let specs: Vec<LiveJobSpec> = (0..10)
        .map(|id| LiveJobSpec {
            id,
            chunk_ids: (0..48).map(|_| rng.gen_range(5_000)).collect(),
        })
        .collect();

    println!("[live] 10 jobs x 48 tasks on 4 workers, payload = Pallas chunk kernel via PJRT");
    let report = leader.run_jobs(&specs, AssignPolicy::Wf).expect("live run");
    let lat = report.latency_summary();
    println!("  tasks executed : {}", report.tasks);
    println!("  throughput     : {:.0} tasks/s", report.throughput_tps());
    println!(
        "  job latency    : mean {:.2} ms / p50 {:.2} ms / p99 {:.2} ms",
        lat.mean, lat.p50, lat.p99
    );
    println!("  checksum       : {:.4}", report.checksum);
    assert!(report.checksum != 0.0, "payload kernel must produce nonzero output");
    leader.shutdown();
    println!("\nlive_cluster OK");
}
