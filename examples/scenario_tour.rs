//! Scenario tour: run FIFO water-filling and OCWF-ACC across every named
//! workload scenario, in parallel, and print the catalog side by side.
//!
//! ```text
//! cargo run --release --offline --example scenario_tour
//! ```

use taos::sched::SchedPolicy;
use taos::sweep::{pool, run_specs, CellSpec};
use taos::trace::scenarios::Scenario;

fn main() {
    // One spec per (scenario, policy): small enough to finish in seconds,
    // fanned out across all cores by the sweep pool.
    let policies = [
        SchedPolicy::fifo(taos::assign::AssignPolicy::Wf),
        SchedPolicy::ocwf(true),
    ];
    let mut specs = Vec::new();
    for (i, sc) in Scenario::ALL.iter().enumerate() {
        let mut cfg = taos::sweep::quick_base(7);
        sc.apply(&mut cfg);
        for policy in policies {
            specs.push(CellSpec {
                cfg: cfg.clone(),
                policy,
                setting: i as f64,
                trial: 0,
            });
        }
    }

    let threads = pool::available_threads();
    println!("running {} cells on {threads} threads\n", specs.len());
    let outcomes = run_specs(&specs, threads).expect("scenario cell failed");

    println!("{:<18} {:>10} {:>10}  note", "scenario", "wf", "ocwf-acc");
    for (i, sc) in Scenario::ALL.iter().enumerate() {
        let wf = outcomes[i * 2].mean_jct();
        let ocwf = outcomes[i * 2 + 1].mean_jct();
        println!(
            "{:<18} {:>10.1} {:>10.1}  {}",
            sc.name(),
            wf,
            ocwf,
            sc.describe()
        );
    }
    println!("\n(`taos repro --fig scenarios --quick --threads 0` runs all six algorithms)");
}
